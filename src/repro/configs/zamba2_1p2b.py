"""zamba2-1.2b [hybrid]: Mamba2 backbone + shared attention blocks.

38L d_model=2048, shared attn 32H (MHA, kv=32, head_dim 64) + shared MLP
d_ff=8192 applied every 6 layers, ssm_state=64, vocab 32000.
[arXiv:2411.15242; hf Zyphra/Zamba2-1.2B]
Recorded simplification (DESIGN.md §5): shared block runs at d_model width
(real Zamba2 concatenates the original embedding; per-invocation LoRAs omitted).
"""

from repro.configs.base import ModelConfig, SSMCfg

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    norm="rmsnorm",
    act="gelu",
    gated_mlp=True,
    rope_theta=10000.0,
    ssm=SSMCfg(kind="mamba2", d_state=64, head_dim=64, expand=2, n_groups=2, conv_width=4),
    attn_every=6,
)
