"""h2o-danube-1.8b [dense]: llama+mistral mix with sliding-window attention.

24L d_model=2560 32H (head_dim 80, GQA kv=8) d_ff=6912 vocab=32000, SWA 4096.
[arXiv:2401.16818; hf h2oai/h2o-danube-1.8b-base]
SWA makes long-context decode O(window): the long_500k cell runs with a
4096-slot ring-buffer KV cache.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab_size=32000,
    norm="rmsnorm",
    act="silu",
    gated_mlp=True,
    rope_theta=10000.0,
    sliding_window=4096,
)
