"""Pallas TPU flash-decode: single-token GQA attention over a KV cache.

This is the paper's skinny-GEMM/GEMV regime (Table 4, §6.1): per kv-head the
kernel streams the (T, dh) cache through VMEM in block_k chunks and performs
(G, dh) x (dh, block_k) matmuls — arithmetic intensity ~G, so the op is HBM
bandwidth-bound exactly as the paper's roofline classifies it. The number of
valid cache slots (`n_valid`) arrives via scalar prefetch so fully-invalid
blocks are skipped before any DMA-issued compute.

Grid: (B, Hkv, kv_blocks), kv innermost (sequential) with online-softmax
scratch carry, G = Hq/Hkv query heads processed together per kv head.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128


def _decode_kernel(nv_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                   scale: float, block_k: int, n_kv: int):
    ki = pl.program_id(2)
    n_valid = nv_ref[0]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(ki * block_k < n_valid)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, dh)
        k = k_ref[0, 0].astype(jnp.float32)  # (block_k, dh)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (G, block_k)
        slot = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(slot < n_valid, s, NEG_INF)

        m_prev = m_scr[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_scr[:, :1], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_decode(q, k, v, n_valid, *, scale: float | None = None,
                 block_k: int = 512, interpret: bool = False):
    """q: (B, Hkv, G, dh); k/v: (B, Hkv, T, dh); n_valid: () int32."""
    B, Hkv, G, dh = q.shape
    T = k.shape[2]
    assert T % block_k == 0, (T, block_k)
    nk = T // block_k
    scale = dh**-0.5 if scale is None else scale
    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k, n_kv=nk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, dh), lambda b, h, ki, nv: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, dh), lambda b, h, ki, nv: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, block_k, dh), lambda b, h, ki, nv: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, dh), lambda b, h, ki, nv: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, LANES), jnp.float32),
            pltpu.VMEM((G, LANES), jnp.float32),
            pltpu.VMEM((G, dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, dh), q.dtype),
        interpret=interpret,
    )(jnp.asarray(n_valid, jnp.int32).reshape(1), q, k, v)
