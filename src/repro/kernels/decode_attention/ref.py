"""Pure-jnp oracle for flash-decode (masked GQA attention over a cache)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k, v, n_valid, *, scale: float | None = None):
    """q: (B, Hkv, G, dh); k/v: (B, Hkv, T, dh); n_valid: () int32."""
    dh = q.shape[-1]
    scale = dh**-0.5 if scale is None else scale
    s = jnp.einsum("bhgd,bhkd->bhgk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    T = k.shape[2]
    valid = jnp.arange(T) < n_valid
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgk,bhkd->bhgd", p.astype(v.dtype), v)
