"""jit'd wrapper for flash-decode: model layout + padding + interpret fallback."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import flash_decode


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_k",))
def decode_attention(q, k, v, n_valid, *, block_k: int = 512):
    """q: (B, Hkv, G, dh); k/v: (B, Hkv, T, dh); n_valid: scalar int32.

    Pads the cache length to a block multiple (padding slots are masked by the
    kernel's n_valid comparison, never attended).
    """
    T = k.shape[2]
    bk = min(block_k, T)
    pad = (-T) % bk
    if pad:
        z = ((0, 0), (0, 0), (0, pad), (0, 0))
        k, v = jnp.pad(k, z), jnp.pad(v, z)
    return flash_decode(q, k, v, n_valid, block_k=bk, interpret=not _on_tpu())
