"""jit'd wrappers for the fused RMSNorm kernel (rank-agnostic, padding)."""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.rmsnorm import rmsnorm_fwd, rmsnorm_residual_fwd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@jax.jit
def rmsnorm(x, scale):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    T = x2.shape[0]
    br = min(256, T)
    pad = (-T) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = rmsnorm_fwd(x2, scale, block_rows=br, interpret=not _on_tpu())
    return out[:T].reshape(shape)


@jax.jit
def rmsnorm_residual(x, res, scale):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    r2 = res.reshape(-1, shape[-1])
    T = x2.shape[0]
    br = min(256, T)
    pad = (-T) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
        r2 = jnp.pad(r2, ((0, pad), (0, 0)))
    y, r = rmsnorm_residual_fwd(x2, r2, scale, block_rows=br, interpret=not _on_tpu())
    return y[:T].reshape(shape), r[:T].reshape(shape)
