"""Pallas TPU fused residual-add + RMSNorm.

The paper (§1.2) calls out kernel fusion as the lever for memory-bound
element-wise/normalization ops: unfused, residual-add + RMSNorm costs
3 reads + 2 writes of the hidden state; fused it is 2 reads + 2 writes and the
mean-square reduction happens in VREGs while the row block is VMEM-resident.

Grid over row blocks; each step normalizes a (block_rows, D) tile in fp32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps) * scale_ref[...]).astype(o_ref.dtype)


def _fused_res_kernel(x_ref, res_ref, scale_ref, o_ref, r_ref, *, eps: float):
    r = x_ref[...].astype(jnp.float32) + res_ref[...].astype(jnp.float32)
    r_ref[...] = r.astype(r_ref.dtype)
    ms = jnp.mean(jnp.square(r), axis=-1, keepdims=True)
    o_ref[...] = (r * jax.lax.rsqrt(ms + eps) * scale_ref[...]).astype(o_ref.dtype)


def rmsnorm_fwd(x, scale, *, eps: float = 1e-5, block_rows: int = 256,
                interpret: bool = False):
    """x: (T, D); scale: (D,) -> (T, D)."""
    T, D = x.shape
    br = min(block_rows, T)
    assert T % br == 0, (T, br)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(T // br,),
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, D), x.dtype),
        interpret=interpret,
    )(x, scale)


def rmsnorm_residual_fwd(x, res, scale, *, eps: float = 1e-5, block_rows: int = 256,
                         interpret: bool = False):
    """Fused y = rmsnorm(x + res) * scale; returns (y, new_residual)."""
    T, D = x.shape
    br = min(block_rows, T)
    assert T % br == 0, (T, br)
    return pl.pallas_call(
        functools.partial(_fused_res_kernel, eps=eps),
        grid=(T // br,),
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((br, D), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, D), x.dtype),
            jax.ShapeDtypeStruct((T, D), x.dtype),
        ],
        interpret=interpret,
    )(x, res, scale)
