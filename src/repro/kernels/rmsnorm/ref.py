"""Pure-jnp oracle for (fused) RMSNorm."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x, scale, *, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def rmsnorm_residual_ref(x, res, scale, *, eps: float = 1e-5):
    r = x.astype(jnp.float32) + res.astype(jnp.float32)
    y = rmsnorm_ref(r, scale, eps=eps)
    return y.astype(x.dtype), r.astype(x.dtype)
