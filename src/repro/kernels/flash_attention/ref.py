"""Pure-jnp oracle for flash attention (dense causal GQA attention)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, scale: float | None = None, window: int | None = None):
    """q: (B, Hq, S, dh); k, v: (B, Hkv, S, dh) -> (B, Hq, S, dh). Causal."""
    B, Hq, S, dh = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    scale = dh**-0.5 if scale is None else scale
    qg = q.reshape(B, Hkv, G, S, dh)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    pos = jnp.arange(S)
    mask = pos[None, :] <= pos[:, None]
    if window is not None:
        mask &= pos[None, :] > pos[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), v)
    return out.reshape(B, Hq, S, dh)
