"""jit'd public wrapper for the flash-attention kernel.

Handles layout adaptation ((B, S, Hkv, G, dh) model layout <-> (B, H, S, dh)
kernel layout), block-size selection, padding to block multiples, and
interpret-mode fallback on CPU (the kernel body runs in the Pallas interpreter
for correctness validation; on TPU it compiles to Mosaic).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_fwd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("window", "block_q", "block_k"))
def flash_attention_bhsd(q, k, v, *, window=None, block_q=128, block_k=128):
    """q: (B, Hq, S, dh); k/v: (B, Hkv, S, dh) — causal flash attention."""
    S = q.shape[2]
    bq, bk = min(block_q, S), min(block_k, S)
    pad = (-S) % bq
    if pad:
        z = ((0, 0), (0, 0), (0, pad), (0, 0))
        q, k, v = jnp.pad(q, z), jnp.pad(k, z), jnp.pad(v, z)
    out = flash_attention_fwd(
        q, k, v, window=window, block_q=bq, block_k=bk, interpret=not _on_tpu()
    )
    return out[:, :, :S] if pad else out


def flash_attention(q, k, v, *, window=None):
    """Model-layout entry: q (B, S, Hkv, G, dh); k/v (B, S, Hkv, dh)."""
    B, S, Hkv, G, dh = q.shape
    qh = jnp.moveaxis(q.reshape(B, S, Hkv * G, dh), 1, 2)
    kh = jnp.moveaxis(k, 1, 2)
    vh = jnp.moveaxis(v, 1, 2)
    out = flash_attention_bhsd(qh, kh, vh, window=window)
    return jnp.moveaxis(out, 2, 1).reshape(B, S, Hkv, G, dh)
