"""Pallas TPU flash-attention forward (causal, GQA, optional sliding window).

TPU-native blocking (DESIGN.md §3): the grid is (batch*q_heads, q_blocks,
kv_blocks) with the kv axis innermost — TPU grids execute sequentially over
the trailing axis, so the online-softmax state (m, l, acc) lives in VMEM
scratch and carries across kv steps. Q/K/V blocks are VMEM-resident via
BlockSpec; the MXU sees (block_q, head_dim) x (head_dim, block_k) matmuls with
hardware-aligned dims (multiples of 128 by default).

Causality and sliding windows are handled two ways:
  * whole-block skip via pl.when (no MXU work issued for fully-masked blocks),
  * within-block masking for the diagonal/window-edge blocks.

m/l scratch is (block_q, 128) lane-replicated, the standard TPU idiom (scalars
cannot live in 8x128-tiled VMEM efficiently).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, scale: float,
               block_q: int, block_k: int, window: int | None, n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    q_start = qi * block_q
    k_start = ki * block_k

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # block-level causal/window liveness
    live = k_start <= q_start + block_q - 1
    if window is not None:
        live &= k_start + block_k - 1 > q_start - window

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (block_q, dh)
        k = k_ref[0, 0].astype(jnp.float32)  # (block_k, dh)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (block_q, block_k)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, :1]  # (block_q, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)  # (block_q, 1)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_scr[:, :1], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, scale: float | None = None,
                        window: int | None = None, block_q: int = 128,
                        block_k: int = 128, interpret: bool = False):
    """q: (B, Hq, S, dh); k, v: (B, Hkv, S, dh) -> (B, Hq, S, dh). Causal."""
    B, Hq, S, dh = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    nq, nk = S // block_q, S // block_k
    scale = dh**-0.5 if scale is None else scale

    kernel = functools.partial(
        _fa_kernel, scale=scale, block_q=block_q, block_k=block_k, window=window,
        n_kv=nk,
    )
    grid = (B * Hq, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, 1, block_k, dh), lambda bh, qi, ki, G=G, Hq=Hq: (bh // Hq, (bh % Hq) // G, ki, 0)),
            pl.BlockSpec((1, 1, block_k, dh), lambda bh, qi, ki, G=G, Hq=Hq: (bh // Hq, (bh % Hq) // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, S, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q.reshape(B * Hq, S, dh), k, v).reshape(B, Hq, S, dh)
