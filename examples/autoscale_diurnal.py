"""Autoscaling under diurnal traffic: dynamic fleet vs static peak.

    PYTHONPATH=src python examples/autoscale_diurnal.py

A day/night (compressed) sinusoidal arrival stream is served two ways:

1. static peak provisioning — the fleet a planner would size for the
   trace's PEAK rate, running all replicas the whole time;
2. a rate-target autoscaler — replicas join (paying a weight-loading
   warmup) as the morning ramp builds and drain away overnight, bounded
   by [min, max].

Both meet the TTFT SLO; the autoscaled fleet does it on measurably fewer
replica-hours, which is the entire point of scaling with the sun. The
run also prints the scale-event timeline against the offered rate so the
warmup lag behind the ramp is visible.

A third run caps the fleet BELOW the peak and sheds the overflow,
pricing each dropped request at `SHED_COST_USD` through
`provisioning_summary(..., shed_cost_usd=)`: the replica-hour bill
shrinks but the total (provisioning + shed) bill shows whether dropping
users was actually cheaper than provisioning for them — the explicit
shedding-vs-overprovisioning trade.

Runs in seconds on CPU: every engine iteration is priced analytically.
"""

from repro.configs import get_config
from repro.sim import LengthDist, SchedConfig, Workload
from repro.cluster import (
    AutoscaleConfig,
    ClusterSpec,
    ReplicaSpec,
    provisioning_summary,
    simulate_cluster,
    summarize_cluster,
)

CFG = get_config("qwen3_14b")
SLO_TTFT = 2.0
PEAK_FLEET = 5  # sized for the envelope peak: ~38 qps / 8 qps-per-replica
SHED_COST_USD = 0.002  # $ a dropped request costs (lost revenue / credit)

wl = Workload(
    name="diurnal-chat", qps=20.0, num_requests=900, arrival="diurnal",
    diurnal_period=45.0, diurnal_amp=0.9,
    prompt=LengthDist("lognormal", 256, 0.4, lo=16, hi=2048),
    output=LengthDist("lognormal", 64, 0.4, lo=4, hi=512), seed=0,
)
reqs = wl.generate()
sched = SchedConfig(policy="continuous", slots=8)


def fleet(n, **kw):
    return ClusterSpec(replicas=tuple(
        ReplicaSpec(hw="h100", pool="mixed", sched=sched, ctx_quantum=32)
        for _ in range(n)), **kw)


print(f"== {CFG.name}: {len(reqs)} requests, diurnal "
      f"{wl.qps:g}±{wl.qps * wl.diurnal_amp:g} qps, "
      f"{wl.diurnal_period:g}s day ==\n")

cache: dict = {}
runs = {}

cres = simulate_cluster(reqs, CFG, fleet(PEAK_FLEET), _cost_cache=cache)
runs["static-peak"] = cres

asc = AutoscaleConfig(policy="rate", min_replicas=1, max_replicas=PEAK_FLEET,
                      interval=1.5, window=5.0, target_qps_per_replica=8.0,
                      slo_ttft=SLO_TTFT)
cres = simulate_cluster(reqs, CFG, fleet(2), autoscale=asc, _cost_cache=cache)
runs["autoscaled"] = cres

# capped fleet: two replicas short of the peak, shedding the overflow —
# cheap in replica-hours, but every drop is priced
capped = AutoscaleConfig(policy="rate", min_replicas=1,
                         max_replicas=PEAK_FLEET - 2, interval=1.5,
                         window=5.0, target_qps_per_replica=8.0,
                         slo_ttft=SLO_TTFT)
cres = simulate_cluster(
    reqs, CFG, fleet(2, shed_depth=16, retry_after=0.5, max_retries=2),
    autoscale=capped, _cost_cache=cache)
runs["capped+shed"] = cres

for name, cres in runs.items():
    s = summarize_cluster(cres, slo_ttft=SLO_TTFT, slo_tpot=0.05)
    prov = provisioning_summary(cres, shed_cost_usd=SHED_COST_USD)
    print(f"{name:<12} ttft_p95={s['ttft_p95']:.2f}s "
          f"goodput={s['goodput_frac']:.0%} "
          f"replicas(peak)={s['peak_replicas']} "
          f"replica-s={prov['replica_hours'] * 3600:.0f} "
          f"cost=${prov['cost_usd']:.4f}"
          + (f" + shed {prov['shed']} x ${SHED_COST_USD} = "
             f"${prov['cost_usd_total']:.4f} total"
             if prov["shed"] else ""))

prov = provisioning_summary(runs["autoscaled"])
print(f"\nautoscaling saved {prov['savings_frac']:.0%} of the static-peak "
      f"bill ({prov['replica_hours'] * 3600:.0f} vs "
      f"{prov['replica_hours_static_peak'] * 3600:.0f} replica-seconds) "
      f"while meeting the {SLO_TTFT:g}s TTFT SLO")

pc = provisioning_summary(runs["capped+shed"], shed_cost_usd=SHED_COST_USD)
pa = provisioning_summary(runs["autoscaled"], shed_cost_usd=SHED_COST_USD)
verdict = ("still cheaper" if pc["cost_usd_total"] < pa["cost_usd_total"]
           else "a false economy")
print(f"capping at {PEAK_FLEET - 2} replicas shed {pc['shed']} requests: "
      f"${pc['cost_usd']:.4f} provisioning + ${pc['shed_cost_usd']:.4f} "
      f"shed = ${pc['cost_usd_total']:.4f} vs the full autoscaler's "
      f"${pa['cost_usd_total']:.4f} — {verdict} at "
      f"${SHED_COST_USD}/drop")

print("\nscale events (offered rate at each):")
for ev in runs["autoscaled"].scale_events:
    print(f"  t={ev['t']:6.2f}s  rate={wl.rate_at(ev['t']):5.1f} qps  "
          f"{ev['action']:<7} r{ev['replica']}"
          + (f" (ready t={ev['ready']:.2f}s)" if ev["action"] == "add" else ""))
