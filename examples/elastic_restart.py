"""Fault tolerance demo: train, checkpoint, 'crash', resume elsewhere.

Simulates a node failure by restoring the checkpoint into a fresh trainer
(in production: a different slice size — see tests/test_multidevice.py for the
cross-mesh reshard) and verifies bitwise-deterministic continuation.

  PYTHONPATH=src python examples/elastic_restart.py
"""
import sys, tempfile

sys.path.insert(0, "src")
from repro.configs import get_config
from repro.configs.base import ParallelConfig, TrainConfig
from repro.data.pipeline import SyntheticLM
from repro.models.transformer import Model
from repro.train.trainer import Trainer

cfg = get_config("starcoder2-3b").reduced()
model = Model(cfg)
with tempfile.TemporaryDirectory() as d:
    tcfg = TrainConfig(steps=20, checkpoint_dir=d, checkpoint_every=5, log_every=5)
    tr = Trainer(model, ParallelConfig(), tcfg)
    state = tr.init_state()
    data = SyntheticLM(cfg.vocab_size, 64, 4)
    state, hist_a = tr.fit(state, data, steps=10)          # steps 0..9, ckpt @5,10
    # --- crash & restart ---
    tr2 = Trainer(model, ParallelConfig(), tcfg)
    state2, step = tr2.resume()
    print(f"resumed at step {step}")
    state2, hist_b = tr2.fit(state2, data, steps=5, start_step=step)
    # reference: continue the original run
    state, hist_ref = tr.fit(state, data, steps=5, start_step=10)
    da, db = hist_ref[-1]["loss"], hist_b[-1]["loss"]
    print(f"continued loss {da:.6f} vs resumed loss {db:.6f}")
    assert abs(da - db) < 1e-5, "resume is not deterministic!"
    print("OK: restart is loss-deterministic")
