"""Prefix-cache study: modeled capacity & eviction flip the capacity plan.

    PYTHONPATH=src python examples/prefix_cache.py

1. Capacity planning under session affinity, twice: first with the legacy
   UNCONDITIONAL `hit_frac` discount (every follow-up request skips 80%
   of its prompt, free of charge, forever), then with the MODELED prefix
   cache (`ClusterSpec.prefix_cache`): a finite byte budget carved out of
   each replica's KV capacity, LRU + TTL eviction, hits computed from
   what is actually resident. The unconditional model claims a 5-replica
   fleet clears the SLO; the modeled cache shows the warmth it assumes
   does not survive eviction/expiry at that load, and the cheapest
   feasible fleet is 6 replicas — a ~$4/hr difference the legacy model
   cannot see.
2. Cross-session sharing: stateless multi-tenant traffic (no sessions,
   shared system prompts via `prefix_group`). The session-only legacy
   model finds NO reuse here at all; the modeled cache shares each
   tenant's prefix across every request that lands on a warm replica and
   recovers most of the prefill.

Runs in ~10 seconds on CPU: every engine iteration is priced
analytically and the planner's candidates share one memoized cost model.
"""

from repro.configs import get_config
from repro.sim import LengthDist, SchedConfig, Workload
from repro.cluster import (
    ClusterSpec,
    PrefixCacheConfig,
    ReplicaSpec,
    plan_capacity,
    simulate_cluster,
    summarize_cluster,
)

CFG = get_config("qwen3_14b")
SLO_TTFT, SLO_TPOT = 0.5, 0.05
sched = SchedConfig(policy="continuous", slots=16)


def show_plan(label: str, plan: dict) -> None:
    for r in plan["rows"]:
        print(f"  {r['replicas']} replicas @ ${r['cost_per_hr']:.2f}/hr: "
              f"goodput {r['goodput_frac']:.1%} "
              f"{'FEASIBLE' if r['feasible'] else 'infeasible'}"
              + (f" (cache: {r['cache_hit_tokens']:.0f} tokens skipped, "
                 f"{r['cache_evictions']:.0f} evictions)"
                 if "cache_hit_tokens" in r else ""))
    best = plan["best"]
    print(f"  -> {label}: "
          + (f"{best['replicas']} replicas at ${best['cost_per_hr']:.2f}/hr"
             if best else "no feasible plan in the sweep"))


# ---- 1. the planner's answer, unconditional vs modeled -------------------
wl = Workload(
    name="chat-sessions", qps=36.0, num_requests=140, arrival="poisson",
    prompt=LengthDist("lognormal", 768, 0.4, lo=32, hi=4096),
    output=LengthDist("lognormal", 96, 0.4, lo=8, hi=512),
    seed=0, num_sessions=16,
)
kw = dict(qps=wl.qps, slo_ttft=SLO_TTFT, slo_tpot=SLO_TPOT, attainment=0.95,
          sched=sched, router="affinity", hit_frac=0.8, ctx_quantum=32,
          min_replicas=4, max_replicas=7, modes=("colocated",))

print(f"== {CFG.name} @ {wl.qps:g} qps, 16 chat sessions, affinity routing, "
      f"ttft<={SLO_TTFT:g}s ==\n")
print("unconditional hit_frac=0.8 discount (legacy model):")
uncond = plan_capacity(CFG, wl, **kw)
show_plan("legacy model buys", uncond)

print("\nmodeled prefix cache (0.3% of KV carved per replica, 3 s TTL):")
finite = plan_capacity(
    CFG, wl, prefix_cache=PrefixCacheConfig(budget_frac=0.003, ttl=3.0), **kw)
show_plan("modeled cache buys", finite)

b_u, b_f = uncond["best"], finite["best"]
if b_u and b_f and b_f["cost_per_hr"] != b_u["cost_per_hr"]:
    print(f"\nThe finite cache FLIPS the plan: "
          f"{b_u['replicas']} -> {b_f['replicas']} replicas "
          f"(${b_u['cost_per_hr']:.2f}/hr -> ${b_f['cost_per_hr']:.2f}/hr). "
          f"The legacy model under-provisions by assuming warmth is free.")

# ---- 2. cross-session sharing the legacy model cannot see ----------------
wl2 = Workload(
    name="multi-tenant-api", qps=24.0, num_requests=96, arrival="poisson",
    prompt=LengthDist("lognormal", 768, 0.4, lo=64, hi=4096),
    output=LengthDist("lognormal", 64, 0.4, lo=8, hi=256),
    seed=1, num_prefix_groups=4, prefix=LengthDist("fixed", 512.0),
)
reqs2 = wl2.generate()
print(f"\n== stateless multi-tenant traffic: 4 shared system prompts of "
      f"512 tokens, NO sessions ==")
for label, pc in (("legacy (session-only) model", None),
                  ("modeled cache (2% of KV)",
                   PrefixCacheConfig(budget_frac=0.02))):
    spec = ClusterSpec(
        replicas=tuple(ReplicaSpec(hw="h100", sched=sched, ctx_quantum=32)
                       for _ in range(3)),
        router="affinity", hit_frac=0.8, prefix_cache=pc)
    s = summarize_cluster(simulate_cluster(reqs2, CFG, spec),
                          slo_ttft=SLO_TTFT, slo_tpot=SLO_TPOT)
    extra = (f", {s['cache_hit_tokens']} prompt tokens skipped "
             f"({s['cache_hit_rate']:.0%} hit rate)"
             if "cache_hit_tokens" in s else "")
    print(f"  {label:<28} ttft_p95={s['ttft_p95']:.2f}s "
          f"goodput={s['goodput_frac']:.1%} "
          f"prefix_hits={s['prefix_hits']}{extra}")
print("  (the legacy discount needs a session to follow; shared prefixes "
      "across sessions are invisible to it)")
