"""Batched serving driven by a `repro.sim` Workload spec.

The SAME workload (arrival process + length distributions, one seed) is
(1) priced by the analytical simulator at full model scale on H100, and
(2) executed by the real slot-based `ServeEngine` on the reduced model —
so the simulated schedule and the executed schedule are comparable
request-for-request.

  PYTHONPATH=src python examples/serve_batched.py
"""
import sys

sys.path.insert(0, "src")
import jax

from repro.configs import get_config
from repro.core.hardware import H100_SXM
from repro.models.transformer import Model
from repro.serve.engine import ServeEngine
from repro.sim import (
    LengthDist,
    SchedConfig,
    ServingCostModel,
    Workload,
    simulate,
    summarize,
    to_engine_requests,
)

cfg = get_config("h2o-danube-1.8b")
wl = Workload(
    name="demo", qps=50.0, num_requests=10, arrival="poisson",
    prompt=LengthDist("lognormal", 24, 0.3, lo=8, hi=48),
    output=LengthDist("lognormal", 12, 0.3, lo=4, hi=16), seed=0,
)
sim_reqs = wl.generate()

# -- 1. analytical schedule at full scale ------------------------------------
cost = ServingCostModel(cfg, H100_SXM, tp=1)
res = simulate(sim_reqs, cost, SchedConfig(policy="continuous", slots=4))
s = summarize(res, slo_ttft=0.5, slo_tpot=0.05)
print(f"sim[{cfg.name} @ {H100_SXM.name}]: "
      f"ttft_p95={s['ttft_p95'] * 1e3:.1f}ms tpot_p95={s['tpot_p95'] * 1e3:.1f}ms "
      f"tok/s={s['tokens_per_s']:.0f} goodput={s['goodput_frac']:.0%}")

# -- 2. execute the identical workload on the reduced model ------------------
rcfg = cfg.reduced()
model = Model(rcfg)
params = model.init(jax.random.PRNGKey(0))
engine = ServeEngine(model, params, max_len=96, slots=4)
done = engine.serve(to_engine_requests(sim_reqs, rcfg.vocab_size, seed=0))
for sim_r, r in zip(sim_reqs, done):
    print(f"req{sim_r.rid}: prompt={sim_r.prompt} generated {len(r.out_tokens)} "
          f"tokens: {r.out_tokens[:8]}...")
assert all(r.done for r in done)
# identical token accounting between the simulated and executed schedules
assert [len(r.out_tokens) for r in done] == [r.output for r in sim_reqs]
# step counts are NOT directly comparable: the engine serves the queue
# immediately (arrival times are a simulator-side concept), while the sim
# spreads admissions over the arrival process
print(f"engine decode steps: {engine.decode_steps}; "
      f"sim decode steps (incl. arrival gaps): {res.decode_steps}")
print("OK")
