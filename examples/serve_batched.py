"""Batched serving with the slot-based continuous-batching engine.

  PYTHONPATH=src python examples/serve_batched.py
"""
import sys

sys.path.insert(0, "src")
import numpy as np
import jax

from repro.configs import get_config
from repro.models.transformer import Model
from repro.serve.engine import Request, ServeEngine

cfg = get_config("h2o-danube-1.8b").reduced()
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
engine = ServeEngine(model, params, max_len=96, slots=4)

rng = np.random.default_rng(0)
reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=24).astype(np.int32),
                max_new_tokens=8 + int(rng.integers(0, 8))) for _ in range(10)]
done = engine.serve(reqs)
for i, r in enumerate(done):
    print(f"req{i}: generated {len(r.out_tokens)} tokens: {r.out_tokens[:8]}...")
assert all(r.done for r in done)
print("OK")
