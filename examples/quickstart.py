"""Quickstart: train a ~small model for a few hundred steps on synthetic data.

  PYTHONPATH=src python examples/quickstart.py [--steps 200]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.configs.base import ParallelConfig, TrainConfig
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.models.transformer import Model
from repro.train.trainer import Trainer

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--arch", default="qwen3-14b")
args = ap.parse_args()

cfg = get_config(args.arch).reduced(num_layers=4, d_model=256, d_ff=512)
model = Model(cfg)
print(f"{cfg.name} (reduced): {model.param_count() / 1e6:.1f}M params")

trainer = Trainer(model, ParallelConfig(), TrainConfig(steps=args.steps, log_every=20))
state = trainer.init_state()
data = Prefetcher(iter(SyntheticLM(cfg.vocab_size, 128, 16)))
state, hist = trainer.fit(state, data, steps=args.steps)
first, last = hist[0]["loss"], hist[-1]["loss"]
print(f"loss {first:.3f} -> {last:.3f} ({'improved' if last < first else 'NO IMPROVEMENT'})")
assert last < first
