"""Cluster-level serving study: colocated vs disaggregated, then an
SLO-driven capacity plan.

    PYTHONPATH=src python examples/cluster_capacity.py

1. Simulates the same bursty workload on a 4-replica H100 fleet organized
   two ways — data-parallel colocated replicas vs a 2-prefill/2-decode
   disaggregated split with comm.p2p-priced KV handoffs — and prints the
   TTFT/TPOT trade the paper's per-group model cannot see on its own.
2. Asks the capacity planner for the cheapest fleet meeting the SLOs at a
   target QPS, sweeping replica count and pool split.

Runs in seconds on CPU: every engine iteration is priced analytically.
"""

from repro.configs import get_config
from repro.sim import LengthDist, SchedConfig, Workload
from repro.cluster import (
    ClusterSpec,
    ReplicaSpec,
    plan_capacity,
    pool_summaries,
    simulate_cluster,
    summarize_cluster,
)

CFG = get_config("qwen3_14b")
SLO_TTFT, SLO_TPOT = 2.0, 0.05

wl = Workload(
    name="bursty-chat", qps=24.0, num_requests=96, arrival="bursty",
    prompt=LengthDist("lognormal", 512, 0.4, lo=32, hi=4096),
    output=LengthDist("lognormal", 128, 0.4, lo=8, hi=1024), seed=0,
)
reqs = wl.generate()
sched = SchedConfig(policy="continuous", slots=16)

print(f"== {CFG.name}: colocated vs disaggregated, 4x H100, "
      f"{wl.qps:g} qps bursty ==")
for pools in (["mixed"] * 4, ["prefill"] * 2 + ["decode"] * 2):
    spec = ClusterSpec(replicas=tuple(
        ReplicaSpec(hw="h100", pool=p, sched=sched, ctx_quantum=32)
        for p in pools))
    cres = simulate_cluster(reqs, CFG, spec)
    s = summarize_cluster(cres, slo_ttft=SLO_TTFT, slo_tpot=SLO_TPOT)
    print(f"\n{cres.mode}: ttft_p95={s['ttft_p95']:.2f}s "
          f"tpot_p95={s['tpot_p95'] * 1e3:.1f}ms "
          f"goodput={s['goodput_frac']:.0%} tok/s={s['tokens_per_s']:.0f} "
          f"xfer_share={s['xfer_share']:.2%}")
    for pool, ps in pool_summaries(cres).items():
        print(f"  {pool:<8} x{ps['replicas']}: util={ps['util_mean']:.0%} "
              f"peak_kv={ps['peak_kv_gb']:.1f}GB")

print(f"\n== capacity plan: cheapest fleet for {wl.qps:g} qps at "
      f"ttft<={SLO_TTFT:g}s, tpot<={SLO_TPOT * 1e3:g}ms ==")
plan = plan_capacity(CFG, wl, qps=wl.qps, slo_ttft=SLO_TTFT, slo_tpot=SLO_TPOT,
                     attainment=0.95, max_replicas=5, ctx_quantum=32,
                     sched=sched)
for r in plan["rows"]:
    tag = (f"{r['prefill']}P/{r['decode']}D" if r["mode"] == "disaggregated"
           else f"{r['replicas']}x mixed")
    note = "FEASIBLE" if r["feasible"] else ("kv-infeasible" if "error" in r
                                             else "misses SLO")
    extra = ("" if "error" in r else
             f" attain={r['goodput_frac']:.0%} ttft_p95={r['ttft_p95']:.2f}s")
    print(f"  {r['mode']:<14} {tag:<10} ${r['cost_per_hr']:>5.2f}/hr{extra}"
          f"  [{note}]")
best = plan["best"]
if best:
    print(f"cheapest feasible: {best['mode']} x{best['replicas']} at "
          f"${best['cost_per_hr']:.2f}/hr")
