"""Predictive + pool-aware autoscaling vs the reactive baselines.

    PYTHONPATH=src python examples/autoscale_predictive.py

Two studies, both pure analytical simulation (seconds on CPU):

1. PREDICTIVE vs REACTIVE on a diurnal chat trace. The reactive rate
   policy only sees arrivals that already happened, so every morning
   ramp costs it warmup + window of SLO debt before capacity lands. The
   predictive policy feeds the KNOWN rate envelope (`Workload.peak_rate`
   — the generator's own diurnal closed form) and an M/G/1 wait estimate
   (service time priced from the serving cost model) into `desired()`,
   so scale-ups LEAD the ramp by the warmup horizon. Target: predictive
   spends no more replica-hours than reactive at >= equal goodput.

2. POOL-AWARE vs TEMPLATE-RATIO scaling of a disaggregated fleet on a
   prefill-heavy trace (long doc-QA prompts, short answers). Fleet-wide
   autoscaling grows prefill and decode pools in lockstep by the spec's
   template ratio, so the compute-bound prefill bottleneck drags a train
   of idle decode replicas with it. Pool-aware scaling
   (`autoscale={"prefill": ..., "decode": ...}`) sizes each pool on its
   own signal — the prefill pool on the envelope through the predictive
   policy, the decode pool on KV occupancy + TPOT debt — and beats the
   template ratio on both goodput and replica-hours.
"""

from dataclasses import replace

from repro.configs import get_config
from repro.sim import LengthDist, SchedConfig, Workload
from repro.cluster import (
    AutoscaleConfig,
    ClusterSpec,
    ReplicaSpec,
    provisioning_summary,
    seed_predictive,
    simulate_cluster,
    summarize_cluster,
)

CFG = get_config("qwen3_14b")
SLO_TTFT, SLO_TPOT = 2.0, 0.05
sched = SchedConfig(policy="continuous", slots=8)


def fleet(pools):
    return ClusterSpec(replicas=tuple(
        ReplicaSpec(hw="h100", pool=p, sched=sched, ctx_quantum=32)
        for p in pools))


def report(name, cres, wl):
    s = summarize_cluster(cres, slo_ttft=SLO_TTFT, slo_tpot=SLO_TPOT)
    prov = provisioning_summary(cres)
    first_add = next((ev for ev in cres.scale_events
                      if ev["action"] == "add"), None)
    lead = ""
    if first_add is not None:
        lead = (f"  first add t={first_add['t']:.1f}s "
                f"(rate then {wl.rate_at(first_add['t']):.0f} qps)")
    print(f"  {name:<11} goodput={s['goodput_frac']:.1%} "
          f"ttft_p95={s['ttft_p95']:.2f}s "
          f"replica-s={prov['replica_hours'] * 3600:.0f} "
          f"peak={prov['peak_replicas']}{lead}")
    return s, prov


# ---------------------------------------------------- 1. predictive vs reactive
wl = Workload(
    name="diurnal-chat", qps=20.0, num_requests=900, arrival="diurnal",
    diurnal_period=45.0, diurnal_amp=0.9,
    prompt=LengthDist("lognormal", 256, 0.4, lo=16, hi=2048),
    output=LengthDist("lognormal", 64, 0.4, lo=4, hi=512), seed=0,
)
reqs = wl.generate()
cache: dict = {}

print(f"== 1. predictive vs reactive: {CFG.name}, {len(reqs)} requests, "
      f"diurnal {wl.qps:g}±{wl.qps * wl.diurnal_amp:g} qps ==")

reactive = AutoscaleConfig(policy="rate", min_replicas=1, max_replicas=5,
                           interval=1.5, window=5.0,
                           target_qps_per_replica=8.0, slo_ttft=SLO_TTFT)
predictive = seed_predictive(
    AutoscaleConfig(min_replicas=1, max_replicas=5, interval=1.5, window=5.0,
                    slo_ttft=SLO_TTFT),
    wl, reqs)

runs = {}
for name, asc in [("reactive", reactive), ("predictive", predictive)]:
    cres = simulate_cluster(reqs, CFG, fleet(["mixed"] * 2),
                            autoscale=asc, _cost_cache=cache)
    runs[name] = report(name, cres, wl)

(s_r, p_r), (s_p, p_p) = runs["reactive"], runs["predictive"]
assert s_p["goodput_frac"] >= s_r["goodput_frac"], \
    "predictive must not trade goodput away"
assert p_p["replica_hours"] <= p_r["replica_hours"], \
    "predictive must not spend more replica-hours"
print(f"  -> predictive meets the SLO better "
      f"({s_p['goodput_frac']:.1%} vs {s_r['goodput_frac']:.1%} goodput, "
      f"ttft_p95 {s_p['ttft_p95']:.2f}s vs {s_r['ttft_p95']:.2f}s) on "
      f"{p_r['replica_hours'] * 3600 - p_p['replica_hours'] * 3600:.0f} "
      f"fewer replica-seconds: the envelope lookahead buys capacity "
      f"BEFORE the ramp needs it and drops it promptly after the crest.")

# ---------------------------------------------- 2. pool-aware vs template ratio
wl_pf = Workload(
    name="doc-qa", qps=6.0, num_requests=400, arrival="diurnal",
    diurnal_period=45.0, diurnal_amp=0.8,
    prompt=LengthDist("lognormal", 2048, 0.3, lo=256, hi=6144),
    output=LengthDist("lognormal", 16, 0.4, lo=2, hi=64), seed=0,
)
reqs_pf = wl_pf.generate()

print(f"\n== 2. pool-aware vs template ratio: prefill-heavy doc-QA "
      f"({wl_pf.prompt.mean:g}-token prompts, {wl_pf.output.mean:g}-token "
      f"answers) ==")

# fleet-wide scaling splits the desired count by the 1P/1D template ratio
template = AutoscaleConfig(policy="rate", min_replicas=2, max_replicas=8,
                           interval=1.0, window=4.0,
                           target_qps_per_replica=2.0, warmup=0.5)
# pool-aware: each pool on its own signal and bounds
base = AutoscaleConfig(min_replicas=1, max_replicas=7, interval=1.0,
                       window=3.0, warmup=0.5, slo_ttft=SLO_TTFT,
                       slo_tpot=SLO_TPOT)
pool_aware = {"prefill": seed_predictive(base, wl_pf, reqs_pf),
              "decode": replace(base, policy="kv_tpot")}

runs = {}
for name, asc in [("template", template), ("pool-aware", pool_aware)]:
    cres = simulate_cluster(reqs_pf, CFG, fleet(["prefill", "decode"]),
                            autoscale=asc, _cost_cache=cache)
    runs[name] = report(name, cres, wl_pf)
    prov = runs[name][1]
    pools = ", ".join(f"{p}: {v['replica_hours'] * 3600:.0f} replica-s "
                      f"(peak {v['peak_replicas']})"
                      for p, v in prov["pools"].items())
    print(f"              [{pools}]")

(s_t, p_t), (s_a, p_a) = runs["template"], runs["pool-aware"]
assert s_a["goodput_frac"] >= s_t["goodput_frac"]
assert p_a["replica_hours"] <= p_t["replica_hours"]
print(f"  -> the template ratio buys a decode replica for every prefill "
      f"replica even though decode is idle on this trace; pool-aware "
      f"scaling holds decode at its floor and spends the budget where "
      f"the bottleneck is ({s_a['goodput_frac']:.1%} vs "
      f"{s_t['goodput_frac']:.1%} goodput at "
      f"{p_t['replica_hours'] * 3600 - p_a['replica_hours'] * 3600:.0f} "
      f"fewer replica-seconds).")
