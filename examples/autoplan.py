"""The paper's model as a planning tool: pick parallelism for a 1024-chip job.

  PYTHONPATH=src python examples/autoplan.py
"""
import sys

sys.path.insert(0, "src")
from repro.core.hardware import TPU_V5E, A100_80G
from repro.core.paper_data import GPT_CONFIGS
from repro.core.planner import plan

for hw, chips in ((TPU_V5E, 1024), (A100_80G, 512)):
    print(f"=== GPT-175B on {chips} x {hw.name}, batch 512, seq 2048 ===")
    for p in plan(GPT_CONFIGS["gpt-175b"], hw, chips, global_batch=512, seq=2048,
                  max_tp=16, top_k=5):
        print(" ", p.describe())
