"""Surviving correlated failure: N-loss capacity planning + chaos replay.

    PYTHONPATH=src python examples/chaos_resilience.py

Two fleets are sized for the same 40 qps chat workload:

1. the STEADY-STATE plan — the cheapest fleet whose SLO attainment
   clears the bar with every replica healthy (`plan_capacity` as before);
2. the RESILIENT plan — `plan_capacity(..., loss_tolerance=2)`: the
   cheapest fleet that still clears the bar after the WORST-CASE loss of
   any 2 replicas (a failure domain: one node holding two replicas).

Both are then replayed through the same fault: a scripted
`repro.cluster.chaos` node failure that kills 2 replicas at t=2 s,
mid-decode. In-flight KV on the dead replicas is lost; displaced
requests re-prefill on the survivors, and — with no autoscaler in the
loop — the dead capacity never comes back.

The steady fleet, sized with zero headroom, degrades: the survivors
absorb the full offered rate plus the re-prefill burst and TTFT blows
through the SLO. The resilient fleet rides through the same event at
>= 99% goodput, because the planner already priced in running without
those two replicas. The premium is the printed $/hr difference — what
the resilience actually costs.

Runs in seconds on CPU: every engine iteration is priced analytically.
"""

from repro.configs import get_config
from repro.sim import LengthDist, SchedConfig, Workload
from repro.cluster import (
    ChaosConfig,
    ChaosEvent,
    ClusterSpec,
    ReplicaSpec,
    plan_capacity,
    simulate_cluster,
    summarize_cluster,
)

CFG = get_config("qwen3_14b")
QPS = 40.0
SLO_TTFT, SLO_TPOT = 2.0, 0.05
ATTAINMENT = 0.99
FAIL_AT, FAIL_COUNT = 2.0, 2

wl = Workload(
    name="chaos-chat", qps=QPS, num_requests=300, arrival="poisson",
    prompt=LengthDist("lognormal", 256, 0.4, lo=16, hi=2048),
    output=LengthDist("lognormal", 64, 0.4, lo=4, hi=512), seed=0,
)
reqs = wl.generate()
sched = SchedConfig(slots=8)

print(f"=== planning for {QPS:g} qps, TTFT p{ATTAINMENT:.0%} <= {SLO_TTFT}s "
      f"===")
plans = {}
for label, loss in (("steady", 0), ("resilient", FAIL_COUNT)):
    plan = plan_capacity(CFG, wl, qps=QPS, slo_ttft=SLO_TTFT,
                         slo_tpot=SLO_TPOT, attainment=ATTAINMENT,
                         sched=sched, ctx_quantum=32, max_replicas=10,
                         modes=("colocated",), loss_tolerance=loss)
    best = plan["best"]
    assert best is not None, f"no feasible {label} plan at {QPS} qps"
    plans[label] = best
    print(f"{label:>10}: {best['replicas']} replicas "
          f"(loss_tolerance={loss}, ${best['cost_per_hr']:.2f}/hr, "
          f"goodput={best['goodput_frac']:.3f}, after-loss goodput="
          f"{best.get('goodput_frac_loss', best['goodput_frac']):.3f})")

premium = plans["resilient"]["cost_per_hr"] - plans["steady"]["cost_per_hr"]
print(f"resilience premium: ${premium:.2f}/hr")

# replay both fleets through the same correlated failure: one node (2
# replicas) dies at t=2 s; picks=(0, 0) deterministically takes the two
# lowest-indexed live replicas
fault = ChaosConfig(script=(
    ChaosEvent(FAIL_AT, "node_failure", count=FAIL_COUNT,
               picks=(0.0,) * FAIL_COUNT),))

print(f"\n=== replaying a {FAIL_COUNT}-replica node failure at "
      f"t={FAIL_AT:g}s ===")
goodput = {}
for label, best in plans.items():
    spec = ClusterSpec(
        replicas=tuple(ReplicaSpec(hw="h100", pool="mixed", sched=sched,
                                   ctx_quantum=32)
                       for _ in range(best["replicas"])),
        chaos=fault)
    cres = simulate_cluster(reqs, CFG, spec)
    s = summarize_cluster(cres, slo_ttft=SLO_TTFT, slo_tpot=SLO_TPOT)
    ch = cres.chaos_stats
    goodput[label] = s["goodput_frac"]
    print(f"{label:>10}: goodput={s['goodput_frac']:.3f} "
          f"ttft_p95={s['ttft_p95']:.2f}s "
          f"displaced={ch['displaced']} "
          f"re_prefill={ch['re_prefill_tokens']} tok "
          f"recovery={ch['recovery_s_max']:.2f}s "
          f"lost={cres.requests_lost}")

print()
assert goodput["resilient"] >= 0.99, (
    f"resilient fleet should ride through the failure: "
    f"goodput {goodput['resilient']:.3f} < 0.99")
assert goodput["steady"] < 0.99, (
    f"steady fleet unexpectedly survived the failure: "
    f"goodput {goodput['steady']:.3f}")
print(f"the {plans['resilient']['replicas']}-replica resilient fleet held "
      f"{goodput['resilient']:.1%} goodput through the failure; the "
      f"{plans['steady']['replicas']}-replica steady fleet fell to "
      f"{goodput['steady']:.1%}. Surviving any {FAIL_COUNT}-replica loss "
      f"costs ${premium:.2f}/hr up front.")
