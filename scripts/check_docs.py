#!/usr/bin/env python
"""Markdown link checker for the docs suite.

    python scripts/check_docs.py

Scans README.md and docs/**/*.md for inline markdown links `[text](target)`
and fails (exit 1) on any RELATIVE link whose target file does not exist
(anchors are stripped; `http(s)://` and `mailto:` links are skipped — no
network in CI). Reference-style link definitions `[label]: target` are
checked the same way.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

INLINE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
REFDEF = re.compile(r"^\s*\[[^\]]+\]:\s+(\S+)", re.MULTILINE)
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def iter_targets(text: str):
    in_code = False
    for line in text.splitlines():
        if line.lstrip().startswith("```"):
            in_code = not in_code
            continue
        if in_code:
            continue
        yield from INLINE.findall(line)
        yield from REFDEF.findall(line)


def check_file(path: Path) -> list[str]:
    broken = []
    for target in iter_targets(path.read_text()):
        if target.startswith(SKIP_PREFIXES):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = (path.parent / rel).resolve()
        if not resolved.exists():
            broken.append(f"{path.relative_to(REPO)}: broken link -> {target}")
    return broken


def main() -> int:
    files = [REPO / "README.md"] + sorted((REPO / "docs").glob("**/*.md"))
    broken: list[str] = []
    for f in files:
        if f.exists():
            broken += check_file(f)
    if broken:
        print("\n".join(broken), file=sys.stderr)
        return 1
    print(f"checked {len(files)} markdown files: all links resolve")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
