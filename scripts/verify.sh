#!/usr/bin/env bash
# Tier-1 verification: the full pytest suite plus fast serving/cluster
# simulation smokes (sub-minute on CPU after the test suite). Run from anywhere.
#
# The fast analytical tier (what CI runs on every push) is:
#     pytest -m "not slow"        # <60s: everything but JAX compile-heavy tests
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# static gates first: the contract linter (exit 1 on any finding not in
# lint_baseline.json — see docs/linting.md) and, when installed, ruff
python -m repro.lint --check
if command -v ruff > /dev/null 2>&1; then
    ruff check .
else
    echo "WARNING: ruff not installed; skipping (CI runs it — see requirements-dev.txt)"
fi

python -m pytest -x -q --durations=15
python -m benchmarks.run serving cluster autoscale

# CLI smokes: tiny workloads, both entry points must run end-to-end
python -m repro.sim --config qwen3_14b --hw h100 --qps 16 --requests 12 \
    --slots 4 --sweep '' --ctx-quantum 32
python -m repro.cluster --config qwen3_14b --hw h100 --replicas 2 --qps 16 \
    --requests 12 --slots 4 --ctx-quantum 32
python -m repro.cluster --config qwen3_14b --hw h100 --replicas 2 --qps 24 \
    --requests 24 --slots 4 --ctx-quantum 32 --mode colocated \
    --arrival diurnal --diurnal-period 20 --autoscale --max-replicas 3 \
    --scale-interval 1 --target-qps 12
# predictive + pool-aware autoscaling smokes
python -m repro.cluster --config qwen3_14b --hw h100 --replicas 2 --qps 24 \
    --requests 24 --slots 4 --ctx-quantum 32 --mode colocated \
    --arrival diurnal --diurnal-period 20 --autoscale \
    --autoscale-policy predictive --max-replicas 3 --scale-interval 1
python -m repro.cluster --config qwen3_14b --hw h100 --replicas 2 --qps 12 \
    --requests 24 --slots 4 --ctx-quantum 32 --mode disaggregated \
    --arrival diurnal --diurnal-period 20 --pool-autoscale \
    --max-replicas 3 --scale-interval 1
# modeled prefix cache: finite LRU+TTL budget over shared-prefix traffic,
# and the planner's cache-budget-share sweep
python -m repro.cluster --config qwen3_14b --hw h100 --replicas 2 --qps 24 \
    --requests 24 --slots 4 --ctx-quantum 32 --mode colocated \
    --router affinity --sessions 4 --prefix-groups 2 --prefix-len 64 \
    --prefix-cache --cache-frac 0.001 --cache-ttl 5
python -m repro.cluster --config qwen3_14b --hw h100 --qps 16 --requests 16 \
    --slots 4 --ctx-quantum 32 --plan --plan-max-replicas 2 \
    --router affinity --sessions 4 --plan-cache-fracs 0.05,0.2
python examples/prefix_cache.py
# trace smoke: a traced autoscaled run must export valid Chrome JSON, and
# a JSONL trace must validate and round-trip through the offline analyzer
TRACE_DIR="$(mktemp -d)"
trap 'rm -rf "$TRACE_DIR"' EXIT
python -m repro.cluster --config qwen3_14b --hw h100 --replicas 2 --qps 24 \
    --requests 24 --slots 4 --ctx-quantum 32 --mode disaggregated \
    --arrival diurnal --diurnal-period 20 --autoscale --max-replicas 3 \
    --scale-interval 1 --target-qps 12 --trace "$TRACE_DIR/t.json"
python -c "import json, sys; json.load(open(sys.argv[1]))" "$TRACE_DIR/t.json"
python -m repro.cluster --config qwen3_14b --hw h100 --replicas 2 --qps 16 \
    --requests 12 --slots 4 --ctx-quantum 32 --mode colocated \
    --trace "$TRACE_DIR/t.jsonl"
python -m repro.obs report "$TRACE_DIR/t.jsonl" --validate-only
python -m repro.obs report "$TRACE_DIR/t.jsonl"

# live SLO monitor smokes: burn-rate alerts at sim time in both CLIs
python -m repro.cluster --config qwen3_14b --hw h100 --replicas 2 --qps 24 \
    --requests 24 --slots 4 --ctx-quantum 32 --mode colocated \
    --slo-window 1 --slo-goodput 0.99 | grep "slo monitor:" > /dev/null
python -m repro.sim --config qwen3_14b --hw h100 --qps 16 --requests 12 \
    --slots 4 --sweep '' --ctx-quantum 32 --policy continuous \
    --slo-window 5 | grep "slo monitor" > /dev/null

# dashboard smoke: --html writes a non-empty page that parses as HTML
python -m repro.obs report "$TRACE_DIR/t.jsonl" --html "$TRACE_DIR/dash.html" \
    --slo-ttft 2.0 --slo-window 1 > /dev/null
python - "$TRACE_DIR/dash.html" <<'PY'
import html.parser, sys
doc = open(sys.argv[1]).read()
assert len(doc) > 2000 and doc.startswith("<!DOCTYPE html>"), "empty dashboard"
p = html.parser.HTMLParser(); p.feed(doc); p.close()
print(f"dashboard ok: {len(doc)} bytes")
PY

# trace-regression gate: regenerate the golden scenario and diff it
# against the checked-in baseline (see tests/goldens/README.md)
python -m repro.cluster --config qwen3_14b --hw h100 --replicas 2 --qps 24 \
    --requests 24 --slots 4 --ctx-quantum 32 --mode colocated \
    --slo-window 1 --slo-goodput 0.99 --trace "$TRACE_DIR/golden.jsonl" \
    > /dev/null
python -m repro.obs diff tests/goldens/cluster_small.jsonl \
    "$TRACE_DIR/golden.jsonl" --fail-on ttft_p99=0.05,e2e_p99=0.05

# chaos smokes: scripted fault injection, a straggler window in the
# single-replica CLI, the admission front door, and the planner's
# N-replica-loss mode; the resilience example must hold its goodput claim
python -m repro.cluster --config qwen3_14b --hw h100 --replicas 3 --qps 24 \
    --requests 24 --slots 4 --ctx-quantum 32 --mode colocated \
    --chaos-crashes 0.1 --chaos-stragglers 0.2 --chaos-seed 9 \
    --chaos-horizon 5 | grep "chaos:" > /dev/null
python -m repro.sim --config qwen3_14b --hw h100 --qps 16 --requests 12 \
    --slots 4 --sweep '' --ctx-quantum 32 --policy continuous \
    --slowdown 3 --slowdown-at 0 --slowdown-for 5 > /dev/null
python -m repro.cluster --config qwen3_14b --hw h100 --replicas 2 --qps 32 \
    --requests 24 --slots 4 --ctx-quantum 32 --mode colocated \
    --admission-policy token_bucket --admission-rate 16 --admission-burst 4 \
    --admission-queue 2 | grep "door \[" > /dev/null
python -m repro.cluster --config qwen3_14b --hw h100 --qps 16 --requests 16 \
    --slots 4 --ctx-quantum 32 --plan --plan-max-replicas 3 --plan-loss 1
python examples/chaos_resilience.py > /dev/null

# engine-core smokes: both entry points must run end-to-end on either
# simulation core (the vectorized fast path and the reference event loop),
# and the parallel planner sweep must work in worker processes
for eng in vectorized reference; do
    python -m repro.sim --config qwen3_14b --hw h100 --qps 16 --requests 12 \
        --slots 4 --sweep '' --ctx-quantum 32 --engine "$eng" > /dev/null
    python -m repro.cluster --config qwen3_14b --hw h100 --replicas 2 \
        --qps 16 --requests 12 --slots 4 --ctx-quantum 32 \
        --engine "$eng" > /dev/null
done
python -m repro.cluster --config qwen3_14b --hw h100 --qps 16 --requests 16 \
    --slots 4 --ctx-quantum 32 --plan --plan-max-replicas 2 \
    --sweep-workers 2 > /dev/null

# sim-speed regression gate: the vectorized engine's steps/second on the
# small config must stay within 30% of the checked-in baseline
python -m benchmarks.sim_speed_bench --sizes small \
    --json "$TRACE_DIR/sim_speed.json" --gate benchmarks/sim_speed_baseline.json

# docs: the generated CLI reference must match the parsers; links resolve
python scripts/gen_cli_docs.py --check
python scripts/check_docs.py
