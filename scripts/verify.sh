#!/usr/bin/env bash
# Tier-1 verification: the full pytest suite plus a fast serving-simulation
# smoke (both sub-minute on CPU). Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q
python -m benchmarks.run serving
